"""Continuous-batching serve layer: pool invariants, scheduler fairness /
preemption, and end-to-end parity with the solo engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kvwire
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serve import (Engine, EngineConfig, PagedConfig, PagedKVPool,
                         RequestParams, Server)

TINY = ModelConfig(name="tiny", family="dense", n_layers=3, d_model=64,
                   vocab_size=256, n_heads=4, n_kv_heads=2, head_dim=16,
                   d_ff=128, dtype="float32", remat="none")


@pytest.fixture(scope="module")
def params():
    return transformer.init_params(TINY, jax.random.key(0))


def _prompts(seed=1, lens=(7, 12, 5)):
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(0, 256, size=n))) for n in lens]


def _solo(params, prompt, n_tokens, **ecfg_kw):
    eng = Engine(TINY, params, EngineConfig(max_len=32, **ecfg_kw))
    out, _ = eng.generate({"tokens": jnp.asarray([prompt], jnp.int32)},
                          steps=n_tokens - 1)
    return np.asarray(out)[0].tolist()


# ---------------------------------------------------------------------------
# pool: alloc / free / defrag invariants
# ---------------------------------------------------------------------------

def test_pool_alloc_free_invariants():
    pool = PagedKVPool(TINY, n_pages=8, page_size=4)
    assert pool.n_allocatable == 7 and pool.n_free == 7
    assert pool.alloc(1, 3) and pool.alloc(2, 2)
    assert pool.n_free == 2 and pool.n_allocated == 5
    handed = pool.pages_of(1) + pool.pages_of(2)
    assert 0 not in handed                     # scratch page never allocated
    assert len(set(handed)) == 5               # no double allocation
    assert not pool.alloc(3, 3)                # all-or-nothing exhaustion
    assert pool.n_free == 2                    # failed alloc takes nothing
    assert pool.free(1) == 3
    assert pool.n_free == 5
    assert pool.alloc(3, 5)                    # freed pages are reusable
    assert pool.free(99) == 0                  # unknown rid is a no-op


def test_pool_table_array_padding():
    pool = PagedKVPool(TINY, n_pages=8, page_size=4)
    pool.alloc(7, 2)
    tbl = pool.table_array(7, 5)
    assert tbl.shape == (5,) and tbl.dtype == np.int32
    assert list(tbl[:2]) == pool.pages_of(7)
    assert (tbl[2:] == 0).all()                # scratch-padded tail


@pytest.mark.parametrize("kv_bits", [None, 8, 2])
def test_pool_defrag_preserves_contents(kv_bits):
    pool = PagedKVPool(TINY, n_pages=10, page_size=4, kv_bits=kv_bits,
                       kv_group=16)
    pool.alloc(1, 2), pool.alloc(2, 3), pool.alloc(3, 1)
    # write recognizable data into request 2's pages (layer pattern pos 0)
    leaf = pool.pages["super"][0]["self"]["k"]
    x = jax.random.normal(jax.random.key(0),
                          (TINY.n_super, 3 * 4, TINY.n_kv_heads,
                           TINY.head_dim))
    contig = (x[:, None] if kv_bits is None
              else kvwire.quantize_kv(x[:, None], kv_bits, 16))
    ids = jnp.asarray(pool.pages_of(2), jnp.int32)
    written = kvwire.scatter_prefill(leaf, contig, ids, stacked=True)
    pool.pages["super"] = (dict(pool.pages["super"][0],
                                self={"k": written,
                                      "v": pool.pages["super"][0]["self"]["v"]}),
                           ) + pool.pages["super"][1:]
    tbl_before = jnp.asarray([pool.table_array(2, 3)])
    before = jax.tree.map(lambda a: kvwire.gather_pages(a[0], tbl_before),
                          written)             # superblock 0's page view

    pool.free(1)                               # leave a hole, then compact
    mapping = pool.defrag()
    assert sorted(p for t in pool.page_tables.values() for p in t) == \
        list(range(1, pool.n_allocated + 1))   # compact, scratch untouched
    assert len(mapping) == 4                   # covers every allocated page
    tbl_after = jnp.asarray([pool.table_array(2, 3)])
    after = jax.tree.map(
        lambda a: kvwire.gather_pages(a[0], tbl_after),
        pool.pages["super"][0]["self"]["k"])
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), before, after)
    assert pool.n_free == pool.n_allocatable - pool.n_allocated


def test_pool_rejects_unsupported_archs():
    ssm = ModelConfig(name="tssm", family="ssm", n_layers=2, d_model=64,
                      vocab_size=256, d_ff=0, rope=False,
                      pattern=(("mamba2", "none"),), ssm_state=16,
                      ssm_head_dim=16, dtype="float32")
    with pytest.raises(ValueError):
        PagedKVPool(ssm, n_pages=8, page_size=4)


def test_pool_bytes_shrink_with_kv_bits():
    sizes = [PagedKVPool(TINY, n_pages=16, page_size=8, kv_bits=b,
                         kv_group=16).nbytes() for b in (None, 8, 4, 2)]
    assert sizes == sorted(sizes, reverse=True)


# ---------------------------------------------------------------------------
# wire-level paged helpers
# ---------------------------------------------------------------------------

def test_gather_scatter_token_roundtrip():
    leaf = kvwire.make_paged_kv(6, 4, 2, 32, bits=8, group_size=16)
    new = jax.random.normal(jax.random.key(3), (2, 1, 2, 32))
    # slot 0 -> page 2 row 1 (pos 9, table [1,2]); slot 1 -> page 4 row 0
    leaf = kvwire.scatter_token(leaf, new, jnp.asarray([2, 4]),
                                jnp.asarray([1, 0]), bits=8, group_size=16)
    table = jnp.asarray([[1, 2], [4, 3]], jnp.int32)
    view = kvwire.dequantize_kv(kvwire.gather_pages(leaf, table), 32)
    np.testing.assert_allclose(np.asarray(view[0, 5]),
                               np.asarray(new[0, 0]), rtol=0.05, atol=0.05)
    np.testing.assert_allclose(np.asarray(view[1, 0]),
                               np.asarray(new[1, 0]), rtol=0.05, atol=0.05)
    assert float(jnp.abs(view[0, 0]).max()) == 0      # untouched rows


# ---------------------------------------------------------------------------
# scheduler: fairness, priority lanes, preemption
# ---------------------------------------------------------------------------

def test_fcfs_completion_order(params):
    srv = Server(TINY, params, EngineConfig(max_len=32),
                 PagedConfig(max_slots=1, page_size=4, n_pages=20,
                             max_context=32))
    done = []
    srv.scheduler.on_complete = lambda c: done.append(c.rid)
    rids = [srv.submit(p, RequestParams(max_new_tokens=4))
            for p in _prompts()]
    srv.drain()
    assert done == rids                        # FCFS with one slot


def test_priority_lane_admitted_first(params):
    srv = Server(TINY, params, EngineConfig(max_len=32),
                 PagedConfig(max_slots=1, page_size=4, n_pages=20,
                             max_context=32))
    done = []
    srv.scheduler.on_complete = lambda c: done.append(c.rid)
    p = _prompts()
    running = srv.submit(p[0], RequestParams(max_new_tokens=4))
    srv.step()                                 # p[0] takes the only slot
    low = srv.submit(p[1], RequestParams(max_new_tokens=4, priority=0))
    high = srv.submit(p[2], RequestParams(max_new_tokens=4, priority=5))
    srv.drain()
    # admission is non-preemptive (the running request finishes), then the
    # high lane wins the freed slot over the earlier-submitted low request
    assert done == [running, high, low]


def test_preemption_recovers_and_is_exact_fp(params):
    prompts = _prompts()[:2]
    ref = [_solo(params, p, 16) for p in prompts]
    srv = Server(TINY, params, EngineConfig(max_len=32),
                 PagedConfig(max_slots=2, page_size=4, n_pages=10,
                             max_context=32))
    rids = [srv.submit(p, RequestParams(max_new_tokens=16)) for p in prompts]
    outs = srv.drain(max_steps=500)
    assert sum(srv.scheduler.request(r).n_preemptions for r in rids) >= 1
    for r, want in zip(rids, ref):
        assert outs[r] == want                 # recompute resume is exact fp
    assert srv.pool.n_allocated == 0           # everything released


def test_priority_request_survives_preemption_and_matches_solo(params):
    """Priority lanes under pool pressure: when pages run out, the
    low-priority request is the preemption victim; the high-priority
    request is never preempted and reproduces its solo-engine greedy
    tokens exactly (and the fp victim recovers exactly too)."""
    low_p, high_p = _prompts()[:2]
    ref_high = _solo(params, high_p, 16)
    ref_low = _solo(params, low_p, 16)
    srv = Server(TINY, params, EngineConfig(max_len=32),
                 PagedConfig(max_slots=2, page_size=4, n_pages=10,
                             max_context=32))
    low = srv.submit(low_p, RequestParams(max_new_tokens=16, priority=0))
    srv.step()                                 # low takes a slot first
    high = srv.submit(high_p, RequestParams(max_new_tokens=16, priority=5))
    outs = srv.drain(max_steps=500)
    assert srv.scheduler.request(low).n_preemptions >= 1
    assert srv.scheduler.request(high).n_preemptions == 0
    assert outs[high] == ref_high              # uninterrupted, solo-exact
    assert outs[low] == ref_low                # fp recompute resume is exact
    assert srv.scheduler.stats()["preemptions"] >= 1


def test_pool_too_small_for_single_request_rejected(params):
    srv = Server(TINY, params, EngineConfig(max_len=32),
                 PagedConfig(max_slots=2, page_size=4, n_pages=3,
                             max_context=32))
    with pytest.raises(ValueError):            # can never fit: reject upfront
        srv.submit(_prompts()[0], RequestParams(max_new_tokens=16))


def test_submit_validation(params):
    srv = Server(TINY, params, EngineConfig(max_len=32),
                 PagedConfig(max_slots=1, page_size=4, n_pages=20,
                             max_context=32))
    with pytest.raises(ValueError):
        srv.submit([], RequestParams())
    with pytest.raises(ValueError):
        srv.submit(list(range(30)), RequestParams(max_new_tokens=8))
    with pytest.raises(ValueError):            # non-positive token budget
        srv.submit([1, 2, 3], RequestParams(max_new_tokens=0))
    with pytest.raises(ValueError):
        srv.submit([1, 2, 3], RequestParams(max_new_tokens=-4))


def test_submit_rejects_request_pool_can_never_hold(params):
    """A request whose full length exceeds the pool's allocatable pages is
    rejected at submit with a clear error instead of live-locking the
    admit loop (pool: 4 pages x 4 = 16 token-slots < 18 needed)."""
    srv = Server(TINY, params, EngineConfig(max_len=32),
                 PagedConfig(max_slots=1, page_size=4, n_pages=5,
                             max_context=32))
    with pytest.raises(ValueError, match="never be admitted"):
        srv.submit(list(range(10)), RequestParams(max_new_tokens=8))
    srv.submit(list(range(10)), RequestParams(max_new_tokens=6))  # 16 fits
    srv.drain(max_steps=200)


def test_completion_carries_tenant_tag(params):
    srv = Server(TINY, params, EngineConfig(max_len=32),
                 PagedConfig(max_slots=1, page_size=4, n_pages=20,
                             max_context=32))
    done = []
    srv.scheduler.on_complete = done.append
    srv.submit(_prompts()[0], RequestParams(max_new_tokens=2,
                                            tenant="gold"))
    srv.submit(_prompts()[1], RequestParams(max_new_tokens=2))
    srv.drain(max_steps=200)
    assert [c.tenant for c in done] == ["gold", None]


# ---------------------------------------------------------------------------
# end-to-end: continuous batching == solo engine, token for token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_bits", [None, 8, 2])
def test_staggered_arrivals_match_solo_greedy(params, kv_bits):
    """The acceptance bar: staggered admissions, shared pool, one jit —
    every request reproduces its solo greedy sequence exactly."""
    kw = dict(kv_bits=kv_bits, kv_group=16) if kv_bits else {}
    prompts = _prompts()
    max_new = [10, 6, 8]
    ref = [_solo(params, p, n, **kw) for p, n in zip(prompts, max_new)]

    streamed = {}
    srv = Server(TINY, params, EngineConfig(max_len=32, **kw),
                 PagedConfig(max_slots=2, page_size=4, n_pages=40,
                             max_context=32),
                 on_token=lambda rid, t: streamed.setdefault(rid,
                                                             []).append(t))
    r0 = srv.submit(prompts[0], RequestParams(max_new_tokens=max_new[0]))
    srv.step(); srv.step()
    r1 = srv.submit(prompts[1], RequestParams(max_new_tokens=max_new[1]))
    srv.step()
    r2 = srv.submit(prompts[2], RequestParams(max_new_tokens=max_new[2]))
    outs = srv.drain(max_steps=200)

    for rid, want in zip((r0, r1, r2), ref):
        assert outs[rid] == want
        assert streamed[rid] == want           # streaming saw every token
    assert srv.engine.decode_compilations == 1  # no per-step retrace


# ---------------------------------------------------------------------------
# per-layer kv plans: heterogeneous page geometry, golden-token parity
# ---------------------------------------------------------------------------

def _kv_plan(kv_map, default=None):
    from repro.plan import QuantPlan
    return QuantPlan.uniform("fp32").with_kv(kv_map, default=default,
                                             kv_group=16)


def test_hetero_pool_layout_and_bytes():
    """A mixed kv map stores one stacked leaf per run of same-format
    superblocks; a uniform map collapses to the homogeneous layout."""
    from repro.serve import cache_nbytes, make_pool_pages, pool_nbytes
    mixed = PagedKVPool(TINY, n_pages=8, page_size=4, kv_bits=(8, None, 2),
                        kv_group=16)
    assert list(mixed.pages) == ["super_segments", "tail"]
    assert len(mixed.pages["super_segments"]) == 3
    assert pool_nbytes(TINY, n_pages=8, page_size=4, kv_bits=(8, None, 2),
                       kv_group=16) == mixed.nbytes()
    uni = make_pool_pages(TINY, n_pages=8, page_size=4, kv_bits=(2, 2, 2),
                          kv_group=16)
    ref = make_pool_pages(TINY, n_pages=8, page_size=4, kv_bits=2,
                          kv_group=16)
    assert jax.tree.structure(uni) == jax.tree.structure(ref)
    assert cache_nbytes(uni) == cache_nbytes(ref)
    # mixed sits strictly between its narrowest and widest uniform pools
    lo = pool_nbytes(TINY, n_pages=8, page_size=4, kv_bits=2, kv_group=16)
    hi = pool_nbytes(TINY, n_pages=8, page_size=4, kv_bits=None)
    assert lo < mixed.nbytes() < hi


def test_hetero_pool_defrag_preserves_contents():
    """Defrag permutes every segment's pages coherently: data written to a
    request's pages at different per-layer bitwidths survives compaction."""
    pool = PagedKVPool(TINY, n_pages=10, page_size=4, kv_bits=(8, None, 2),
                       kv_group=16)
    pool.alloc(1, 2), pool.alloc(2, 3), pool.alloc(3, 1)
    x = jax.random.normal(jax.random.key(0),
                          (1, 3 * 4, TINY.n_kv_heads, TINY.head_dim))
    ids = jnp.asarray(pool.pages_of(2), jnp.int32)
    segs = list(pool.pages["super_segments"])
    written = []
    for s, seg in enumerate(segs):
        leaf = seg[0]["self"]["k"]
        bits = (8, None, 2)[s]
        contig = (x[:, None] if bits is None
                  else kvwire.quantize_kv(x[:, None], bits, 16))
        w = kvwire.scatter_prefill(leaf, contig, ids, stacked=True)
        segs[s] = (dict(seg[0], self={"k": w, "v": seg[0]["self"]["v"]}),)
        written.append(w)
    pool.pages["super_segments"] = segs
    tbl = jnp.asarray([pool.table_array(2, 3)])
    before = [jax.tree.map(lambda a: kvwire.gather_pages(a[0], tbl), w)
              for w in written]

    pool.free(1)
    mapping = pool.defrag()
    assert len(mapping) == 4
    tbl2 = jnp.asarray([pool.table_array(2, 3)])
    for s, want in enumerate(before):
        got = jax.tree.map(
            lambda a: kvwire.gather_pages(a[0], tbl2),
            pool.pages["super_segments"][s][0]["self"]["k"])
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), want, got)


@pytest.mark.parametrize("kv_bits", [None, 8, 2])
def test_uniform_kv_plan_matches_uniform_kv_engine(params, kv_bits):
    """Golden-token parity, degenerate case: a PagedEngine under a plan
    whose kv map is uniform reproduces the plain uniform-kv engine
    token-for-token (and the solo reference), with one compiled step."""
    kw = dict(kv_bits=kv_bits, kv_group=16) if kv_bits else {}
    prompts = _prompts()
    max_new = [8, 6, 7]
    ref = [_solo(params, p, n, **kw) for p, n in zip(prompts, max_new)]

    plan = _kv_plan({}, default=kv_bits)
    srv_plan = Server(TINY, params,
                      EngineConfig(max_len=32, plan=plan, backend="ref"),
                      PagedConfig(max_slots=2, page_size=4, n_pages=40,
                                  max_context=32))
    srv_uni = Server(TINY, params, EngineConfig(max_len=32, **kw),
                     PagedConfig(max_slots=2, page_size=4, n_pages=40,
                                 max_context=32))
    outs = []
    for srv in (srv_plan, srv_uni):
        rids = [srv.submit(p, RequestParams(max_new_tokens=n))
                for p, n in zip(prompts, max_new)]
        done = srv.drain(max_steps=200)
        outs.append([done[r] for r in rids])
        assert srv.engine.decode_compilations == 1
    assert outs[0] == outs[1] == ref
    # and the plan's pool collapsed to the homogeneous layout
    assert "super" in srv_plan.engine.new_pool().pages


def test_mixed_kv_paged_matches_solo_reference(params):
    """The acceptance bar: a genuinely mixed per-layer kv plan served
    through the heterogeneous paged pool reproduces the solo (non-paged)
    mixed-kv ``engine.generate`` reference token-for-token, decode
    compiled once."""
    plan = _kv_plan({"layer.0": 8, "layer.2": 2}, default=None)
    prompts = _prompts()
    max_new = [10, 6, 8]
    solo = []
    for p, n in zip(prompts, max_new):
        eng = Engine(TINY, params, EngineConfig(max_len=32, plan=plan,
                                                backend="ref"))
        out, _ = eng.generate({"tokens": jnp.asarray([p], jnp.int32)},
                              steps=n - 1)
        solo.append(np.asarray(out)[0].tolist())

    srv = Server(TINY, params,
                 EngineConfig(max_len=32, plan=plan, backend="ref"),
                 PagedConfig(max_slots=2, page_size=4, n_pages=40,
                             max_context=32))
    r0 = srv.submit(prompts[0], RequestParams(max_new_tokens=max_new[0]))
    srv.step(); srv.step()
    r1 = srv.submit(prompts[1], RequestParams(max_new_tokens=max_new[1]))
    srv.step()
    r2 = srv.submit(prompts[2], RequestParams(max_new_tokens=max_new[2]))
    outs = srv.drain(max_steps=200)
    for rid, want in zip((r0, r1, r2), solo):
        assert outs[rid] == want
    assert srv.engine.decode_compilations == 1
    assert "super_segments" in srv.pool.pages  # genuinely heterogeneous


def test_mixed_weights_and_kv_paged_matches_solo(params):
    """Mixed weights AND mixed cache in one plan through the paged path."""
    from repro.plan import QuantPlan
    from repro.plan.plan import candidates_for
    cands = candidates_for(TINY, ["lq8w", "lq2w"])
    plan = QuantPlan.from_assignment(
        {"layer.0": cands["lq8w"]}, default=cands["lq2w"],
        kv_bits={"layer.0": 8}, kv_default=2, kv_group=16)
    prompt = _prompts()[0]
    eng = Engine(TINY, params, EngineConfig(max_len=32, plan=plan,
                                            backend="ref"))
    out, _ = eng.generate({"tokens": jnp.asarray([prompt], jnp.int32)},
                          steps=9)
    solo = np.asarray(out)[0].tolist()
    srv = Server(TINY, params,
                 EngineConfig(max_len=32, plan=plan, backend="ref"),
                 PagedConfig(max_slots=2, page_size=4, n_pages=40,
                             max_context=32))
    rid = srv.submit(prompt, RequestParams(max_new_tokens=10))
    outs = srv.drain(max_steps=200)
    assert outs[rid] == solo
    assert srv.engine.decode_compilations == 1


def test_completions_and_stats(params):
    srv = Server(TINY, params, EngineConfig(max_len=32),
                 PagedConfig(max_slots=2, page_size=4, n_pages=20,
                             max_context=32))
    rid = srv.submit(_prompts()[0], RequestParams(max_new_tokens=1))
    events = srv.step()                        # completes at admission
    assert [c.rid for c in events] == [rid]
    assert len(events[0].tokens) == 1
    s = srv.stats()
    assert s["active"] == 0 and s["queued"] == 0
    assert s["pool_bytes"] > 0
