"""Mixed-precision planner: plan round-trip, segmented model parity,
cost model accounting, search optimality, and planned serving parity."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import schemes
from repro.kernels import ops as kops
from repro.models import convnet, transformer
from repro.models.config import ModelConfig
from repro.models.layers import NO_QUANT, PlanPolicy, QuantPolicy
from repro.plan import (QuantPlan, candidate_costs, greedy_search,
                        layer_cost, layer_dense_params, pareto_frontier,
                        plan_cost, profile_sensitivity, uniform_result,
                        weight_bytes)
from repro.plan.plan import candidates_for, layer_name
from repro.serve import Engine, EngineConfig, PagedConfig, RequestParams, \
    Server

TINY = ModelConfig(name="tiny", family="dense", n_layers=4, d_model=64,
                   vocab_size=256, n_heads=4, n_kv_heads=2, head_dim=16,
                   d_ff=128, dtype="float32", remat="none")

CANDS = candidates_for(TINY, ["lq8w", "lq4w", "lq2w"])


@pytest.fixture(scope="module")
def params():
    return transformer.init_params(TINY, jax.random.key(0))


def _batch(b=2, l=8, seed=1):
    return {"tokens": jax.random.randint(jax.random.key(seed), (b, l), 0,
                                         TINY.vocab_size, jnp.int32)}


def _mixed_plan():
    return QuantPlan.from_assignment(
        {"layer.0": CANDS["lq8w"], "layer.1": CANDS["lq8w"],
         "layer.2": CANDS["lq2w"]}, default="fp32",
        meta={"origin": "test"})


# ---------------------------------------------------------------------------
# QuantPlan: resolve / JSON round trip / validation
# ---------------------------------------------------------------------------

def test_plan_json_roundtrip():
    plan = _mixed_plan()
    back = QuantPlan.from_json(plan.to_json())
    assert back == plan
    # registered schemes serialize by name, custom configs by field dict
    obj = json.loads(QuantPlan.uniform("lq4").to_json())
    assert obj["default"] == "lq4"
    obj2 = json.loads(plan.to_json())
    assert obj2["layers"]["layer.0"]["w_bits"] == 8       # gs=64, not 128


def test_plan_resolve_fills_default_and_validates():
    plan = _mixed_plan()
    cfgs = plan.resolve(TINY)
    assert len(cfgs) == TINY.n_layers
    assert cfgs[3] == schemes.FP32                        # default fills
    assert cfgs[0].w_bits == 8 and cfgs[2].w_bits == 2
    with pytest.raises(ValueError, match="out of range"):
        QuantPlan.from_assignment({"layer.9": "lq8"}).resolve(TINY)
    with pytest.raises(ValueError, match="group_size"):
        QuantPlan.uniform("lq8").resolve(TINY)            # gs 128 vs d64
    with pytest.raises(ValueError, match="duplicate"):
        QuantPlan(assignments=(("layer.0", schemes.FP32),
                               ("layer.0", schemes.FP32)))


def test_uniform_plan_is_trivial():
    plan = QuantPlan.uniform(CANDS["lq8w"])
    assert plan.is_uniform
    assert set(plan.resolve(TINY)) == {CANDS["lq8w"]}


# ---------------------------------------------------------------------------
# per-layer kv_bits: schema, JSON round trip, resolve validation
# ---------------------------------------------------------------------------

def _kv_plan(**kw):
    base = dict(kv_bits={"layer.0": 8, "layer.2": 2}, default=None,
                kv_group=16)
    base.update(kw)
    return QuantPlan.uniform("fp32").with_kv(
        base["kv_bits"], default=base["default"],
        kv_group=base["kv_group"])


def test_kv_plan_json_roundtrip():
    plan = _kv_plan()
    back = QuantPlan.from_json(plan.to_json())
    assert back == plan
    obj = json.loads(plan.to_json())
    assert obj["kv"] == {"default": None, "group": 16,
                         "layers": {"layer.0": 8, "layer.2": 2}}
    # plans without a kv map serialize exactly as before (no "kv" key)
    assert "kv" not in json.loads(_mixed_plan().to_json())
    assert not _mixed_plan().has_kv and _kv_plan().has_kv


def test_kv_plan_resolve_fills_default():
    assert _kv_plan().resolve_kv(TINY) == (8, None, 2, None)
    assert _kv_plan(default=4).resolve_kv(TINY) == (8, 4, 2, 4)
    uni, bits = _kv_plan(kv_bits={}, default=8).uniform_kv(TINY)
    assert uni and bits == 8
    uni, _ = _kv_plan().uniform_kv(TINY)
    assert not uni


def test_kv_plan_rejects_non_power_of_two_bits():
    for bad in (6, 3, 0, 16):
        with pytest.raises(ValueError, match="kv_bits"):
            QuantPlan.uniform("fp32").with_kv({"layer.0": bad}, kv_group=16)
    with pytest.raises(ValueError, match="kv_bits"):
        QuantPlan.uniform("fp32").with_kv(default=5)


def test_kv_plan_rejects_missing_layers():
    plan = QuantPlan.uniform("fp32").with_kv({"layer.9": 8}, kv_group=16)
    with pytest.raises(ValueError, match="out of range"):
        plan.resolve_kv(TINY)
    with pytest.raises(ValueError, match="out of range"):
        plan.resolve(TINY)                     # resolve() validates kv too


def test_kv_plan_rejects_layers_without_caches():
    hybrid = ModelConfig(name="thyb", family="hybrid", n_layers=3,
                         d_model=64, vocab_size=256, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=128, lru_width=64,
                         pattern=(("rglru", "swiglu"), ("rglru", "swiglu"),
                                  ("attn", "swiglu")),
                         dtype="float32", remat="none")
    ok = QuantPlan.uniform("fp32").with_kv({"layer.2": 8}, kv_group=16)
    assert ok.resolve_kv(hybrid) == (None, None, 8)
    bad = QuantPlan.uniform("fp32").with_kv({"layer.0": 8}, kv_group=16)
    with pytest.raises(ValueError, match="no quantizable cache"):
        bad.resolve_kv(hybrid)


def test_kv_plan_rejects_group_not_dividing_head_dim():
    plan = QuantPlan.uniform("fp32").with_kv({"layer.0": 8}, kv_group=12)
    with pytest.raises(ValueError, match="head_dim"):
        plan.resolve_kv(TINY)
    with pytest.raises(ValueError, match="duplicate kv_bits"):
        QuantPlan(kv_bits=(("layer.0", 8), ("layer.0", 2)))


# ---------------------------------------------------------------------------
# segmented model path
# ---------------------------------------------------------------------------

def test_fp_plan_forward_matches_unplanned(params):
    batch = _batch()
    want, _ = transformer.forward(params, TINY, batch, policy=NO_QUANT,
                                  training=False)
    pol = QuantPlan.uniform("fp32").policy(TINY, mode="serve", backend="ref")
    got, _ = transformer.forward(params, TINY, batch, policy=pol,
                                 training=False)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_uniform_plan_matches_uniform_quantize(params):
    batch = _batch()
    plan = QuantPlan.uniform(CANDS["lq4w"])
    qp_plan = transformer.quantize_params(params, TINY, plan)
    got, _ = transformer.forward(
        qp_plan, TINY, batch,
        policy=plan.policy(TINY, mode="serve", backend="ref"),
        training=False)
    qp_uni = transformer.quantize_params(params, TINY, CANDS["lq4w"])
    want, _ = transformer.forward(
        qp_uni, TINY, batch,
        policy=QuantPolicy.serve(CANDS["lq4w"], backend="ref"),
        training=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_plan_segments_grouping():
    a, b = CANDS["lq8w"], CANDS["lq2w"]
    segs = transformer.plan_segments([a, a, b, a], 1, 4)
    assert [(s, n) for s, n, _ in segs] == [(0, 2), (2, 1), (3, 1)]
    segs2 = transformer.plan_segments([a, a, a, a], 2, 2)
    assert len(segs2) == 1 and segs2[0][1] == 2


def test_planned_quantize_packs_per_layer(params):
    plan = _mixed_plan()
    qp = transformer.quantize_params(params, TINY, plan)
    segs = qp["decoder"]["super_segments"]
    assert len(segs) == 3                     # [8,8] [2] [fp]
    w0 = segs[0][0]["mixer"]["wq"]["w"]
    w1 = segs[1][0]["mixer"]["wq"]["w"]
    w2 = segs[2][0]["mixer"]["wq"]["w"]
    assert isinstance(w0, kops.QWeight) and w0.bits == 8
    assert w0.packed.shape[0] == 2            # two stacked superblocks
    assert isinstance(w1, kops.QWeight) and w1.bits == 2
    assert not isinstance(w2, kops.QWeight)   # fp layer untouched


def test_plan_params_policy_mismatch_raises(params):
    qp = transformer.quantize_params(params, TINY, _mixed_plan())
    other = QuantPlan.from_assignment({"layer.0": CANDS["lq8w"]},
                                      default=CANDS["lq2w"])
    with pytest.raises(ValueError, match="mismatch"):
        transformer.forward(qp, TINY, _batch(),
                            policy=other.policy(TINY, backend="ref"),
                            training=False)


def test_planned_qat_matches_packed_serve(params):
    """Fake-quant profiling numerics track the packed deployment."""
    batch = _batch()
    plan = _mixed_plan()
    qat, _ = transformer.forward(params, TINY, batch,
                                 policy=plan.policy(TINY, mode="qat"),
                                 training=False)
    qp = transformer.quantize_params(params, TINY, plan)
    serve, _ = transformer.forward(
        qp, TINY, batch, policy=plan.policy(TINY, backend="ref"),
        training=False)
    np.testing.assert_allclose(np.asarray(qat), np.asarray(serve),
                               rtol=1e-4, atol=1e-4)


def test_convnet_per_layer_policy():
    cfg = convnet.MINI_CNN
    params = convnet.init_params(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, cfg.input_hw, cfg.input_hw,
                                              cfg.in_ch))
    fp = convnet.apply(params, cfg, x)
    n = convnet.n_quant_layers(cfg)
    cfgs = tuple(schemes.QuantConfig(w_bits=2, group_size=16)
                 if i == 0 else schemes.FP32 for i in range(n))
    mixed = convnet.apply(params, cfg, x,
                          policy=PlanPolicy("qat", cfgs))
    assert float(jnp.abs(mixed - fp).max()) > 0    # layer 0 quantized
    with pytest.raises(ValueError):
        convnet.apply(params, cfg, x, policy=PlanPolicy("qat", cfgs[:2]))


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_weight_bytes_matches_qweight_nbytes():
    k = n = 256
    w = jax.random.normal(jax.random.key(0), (k, n))
    for bits in (8, 4, 2, 1):
        qcfg = schemes.QuantConfig(w_bits=bits, group_size=64)
        qw = kops.quantize_weight(w, bits, 64)
        assert weight_bytes(k * n, qcfg) == qw.nbytes()


def test_layer_costs_monotone_in_bits():
    n = 100_000
    by_bits = [layer_cost(n, schemes.QuantConfig(w_bits=b, group_size=64))
               for b in (8, 4, 2)]
    assert by_bits[0].bytes > by_bits[1].bytes > by_bits[2].bytes
    assert by_bits[0].ms > by_bits[1].ms > by_bits[2].ms     # memory-bound
    fp = layer_cost(n, schemes.FP32)
    assert fp.bytes == 4.0 * n and fp.bytes > by_bits[0].bytes


def test_lut_op_reduction_in_cost_model():
    n = 90_000
    lut_cfg = schemes.QuantConfig(w_bits=8, a_bits=2, lut=True,
                                  group_size=9)
    plain = layer_cost(n, schemes.QuantConfig(w_bits=8, group_size=9))
    lut = layer_cost(n, lut_cfg)
    assert lut.multiplies == n / 9                  # 1 mult per region
    assert lut.adds == (n / 9) * 3                  # 2^2 - 1 per region
    assert plain.multiplies == n


def test_plan_cost_totals(params):
    sizes = layer_dense_params(TINY)
    assert len(sizes) == TINY.n_layers and len(set(sizes)) == 1
    cfgs = _mixed_plan().resolve(TINY)
    total = plan_cost(TINY, cfgs)
    assert total["bytes"] == sum(weight_bytes(s, c)
                                 for s, c in zip(sizes, cfgs))
    # mixed plan sits between uniform-2 and fp
    lo = plan_cost(TINY, (CANDS["lq2w"],) * 4)["bytes"]
    hi = plan_cost(TINY, (schemes.FP32,) * 4)["bytes"]
    assert lo < total["bytes"] < hi


# ---------------------------------------------------------------------------
# kv cost model + joint (weight x kv) search space
# ---------------------------------------------------------------------------

def test_kv_bytes_per_token_matches_pool_pages():
    """The per-token kv price is exactly one pool page's bytes per layer
    divided by page_size, for every wire format."""
    from repro.plan import layer_kv_bytes_per_token
    from repro.serve import pool_nbytes
    page_size, n_pages = 4, 6
    for bits in (None, 8, 4, 2, 1):
        per_tok = sum(layer_kv_bytes_per_token(TINY, i, bits, 16)
                      for i in range(TINY.n_layers))
        total = pool_nbytes(TINY, n_pages=n_pages, page_size=page_size,
                            kv_bits=bits, kv_group=16)
        assert per_tok * page_size * n_pages == total


def test_context_aware_kv_tokens_price_equals_pool_nbytes():
    """The satellite bar: pricing the cache at the serve cell's real
    capacity (n_pages * page_size tokens) makes the plan's kv bytes match
    ``pool_nbytes`` EXACTLY — plan and pool budgets share one currency,
    including heterogeneous per-layer maps."""
    from repro.plan import plan_kv_cost
    from repro.serve import pool_nbytes
    page_size, n_pages = 4, 6
    for kv_map in [(8, 8, 8, 8), (8, None, 2, 2), (2, 1, 4, 8),
                   (None,) * 4]:
        priced = plan_kv_cost(TINY, kv_map, kv_group=16,
                              tokens=n_pages * page_size)["bytes"]
        exact = pool_nbytes(TINY, n_pages=n_pages, page_size=page_size,
                            kv_bits=kv_map, kv_group=16)
        assert priced == exact


def test_launch_plan_defaults_kv_tokens_to_cell_geometry(tmp_path):
    """``launch.plan --n-pages/--page-size`` without --kv-tokens prices
    the joint search at the cell capacity; the emitted plan records it."""
    from repro.launch import plan as launch_plan
    out = str(tmp_path / "plan.json")
    launch_plan.main([
        "--arch", "llama3.2-1b", "--schemes", "lq8w,lq4w",
        "--budget-mb", "0.2", "--kv", "8,2", "--kv-group", "16",
        "--n-pages", "6", "--page-size", "4",
        "--batches", "1", "--batch-size", "2", "--seq-len", "16",
        "--out", out])
    plan = QuantPlan.load(out)
    assert dict(plan.meta)["kv_tokens"] == 24      # 6 pages x 4 tokens


def test_kv_costs_monotone_and_labels():
    from repro.plan import (kv_bits_of_label, kv_candidate_costs, kv_label,
                            plan_kv_cost)
    assert kv_label(None) == "kvfp" and kv_label(8) == "kv8"
    assert kv_bits_of_label("kvfp") is None and kv_bits_of_label("kv2") == 2
    costs = kv_candidate_costs(TINY, (None, 8, 4, 2, 1), kv_group=16,
                               tokens=10)
    row = costs["layer.0"]
    seq = [row[kv_label(b)]["bytes"] for b in (None, 8, 4, 2, 1)]
    assert seq == sorted(seq, reverse=True)     # fp > 8 > 4 > 2 > 1
    assert row["kv8"]["bytes"] == 10 * row["kv8"]["bytes_per_token"]
    total = plan_kv_cost(TINY, (8, 2, None, 1), kv_group=16)
    assert total["bytes_per_token"] == sum(total["per_layer"])
    with pytest.raises(ValueError):
        plan_kv_cost(TINY, (8, 2), kv_group=16)


def test_joint_space_and_split():
    from repro.plan import joint_space, split_joint_assignment
    w = {"layer.0": {"lq8w": {"bytes": 100.0, "kl": 0.1, "ms": 7.0}}}
    kv = {"layer.0": {"kv2": {"bytes": 5.0, "kl": 0.02,
                              "bytes_per_token": 1.0}}}
    j = joint_space(w, kv)
    cell = j["layer.0"]["lq8w|kv2"]
    assert cell["bytes"] == 105.0 and cell["kl"] == pytest.approx(0.12)
    assert cell["ms"] == 7.0 and cell["bytes_per_token"] == 1.0
    ws, kvs = split_joint_assignment({"layer.0": "lq8w|kv2"})
    assert ws == {"layer.0": "lq8w"} and kvs == {"layer.0": "kv2"}
    with pytest.raises(ValueError, match="different layers"):
        joint_space(w, {"layer.1": {}})


def test_kv_search_confined_to_attention_layers():
    """Layers without a searchable cache (rglru, mamba2) get the fp cell
    only, in both grids, so a joint search on a hybrid arch emits a plan
    that resolve_kv() accepts instead of assigning bits to cache-less
    mixers (and never deploys unprofiled SSM-state quantization)."""
    from repro.plan import kv_candidate_costs, kv_searchable
    hybrid = ModelConfig(name="thyb", family="hybrid", n_layers=3,
                         d_model=64, vocab_size=256, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=128, lru_width=64,
                         pattern=(("rglru", "swiglu"), ("rglru", "swiglu"),
                                  ("attn", "swiglu")),
                         dtype="float32", remat="none")
    assert [kv_searchable(hybrid, i) for i in range(3)] == \
        [False, False, True]
    costs = kv_candidate_costs(hybrid, (8, 4, 2), kv_group=16)
    assert set(costs["layer.0"]) == {"kvfp"}
    assert set(costs["layer.2"]) == {"kv8", "kv4", "kv2"}
    params = transformer.init_params(hybrid, jax.random.key(0))
    from repro.plan import profile_kv_sensitivity
    sens = profile_kv_sensitivity(params, hybrid, [_batch()], (8, 2),
                                  kv_group=16)
    assert sens["layer.0"] == {"kvfp": {"mse": 0.0, "kl": 0.0}}
    assert set(sens["layer.2"]) == {"kv8", "kv2"}


def test_joint_search_descends_both_axes():
    """Greedy over the joint grid narrows the cache where kv sensitivity
    is negligible and the weights where weight sensitivity is."""
    from repro.plan import greedy_search, joint_space
    w_sens = {"l0": {"w8": {"kl": 0.0}, "w2": {"kl": 1.0}},
              "l1": {"w8": {"kl": 0.0}, "w2": {"kl": 0.001}}}
    w_cost = {"l0": {"w8": {"bytes": 80.0}, "w2": {"bytes": 20.0}},
              "l1": {"w8": {"bytes": 80.0}, "w2": {"bytes": 20.0}}}
    kv_sens = {"l0": {"kv8": {"kl": 0.0}, "kv2": {"kl": 0.0005}},
               "l1": {"kv8": {"kl": 0.0}, "kv2": {"kl": 2.0}}}
    kv_cost = {"l0": {"kv8": {"bytes": 40.0}, "kv2": {"bytes": 10.0}},
               "l1": {"kv8": {"bytes": 40.0}, "kv2": {"bytes": 10.0}}}
    r = greedy_search(joint_space(w_sens, kv_sens),
                      joint_space(w_cost, kv_cost), budget=150.0)
    assert r.feasible
    # l0: cheap cache, expensive weights stay wide; l1: the reverse
    assert r.assignment == {"l0": "w8|kv2", "l1": "w2|kv8"}
    plan = r.joint_plan({"w8": CANDS["lq8w"], "w2": CANDS["lq2w"]},
                        kv_group=16)
    assert dict(plan.kv_bits) == {"l0": 2, "l1": 8}


def test_planned_quantize_splits_segments_on_kv_boundary(params):
    """Identical weights but a kv boundary mid-stack: the packed params
    must segment on the combined key so the walker's scan bodies see one
    wire shape each."""
    plan = QuantPlan.uniform(CANDS["lq4w"]).with_kv(
        {"layer.0": 8, "layer.1": 8}, default=2, kv_group=16)
    qp = transformer.quantize_params(params, TINY, plan)
    segs = qp["decoder"]["super_segments"]
    assert len(segs) == 2                      # [kv8, kv8] | [kv2, kv2]
    assert all(isinstance(s[0]["mixer"]["wq"]["w"], kops.QWeight)
               for s in segs)
    assert segs[0][0]["mixer"]["wq"]["w"].packed.shape[0] == 2


# ---------------------------------------------------------------------------
# search
# ---------------------------------------------------------------------------

def _toy_problem():
    sens = {"layer.0": {"w": {"kl": 0.001}, "n": {"kl": 1.0}},
            "layer.1": {"w": {"kl": 0.0001}, "n": {"kl": 0.01}}}
    costs = {"layer.0": {"w": {"bytes": 100.0}, "n": {"bytes": 25.0}},
             "layer.1": {"w": {"bytes": 100.0}, "n": {"bytes": 25.0}}}
    return sens, costs


def test_greedy_downgrades_least_sensitive_first():
    sens, costs = _toy_problem()
    r = greedy_search(sens, costs, budget=125.0)
    assert r.feasible
    assert r.assignment == {"layer.0": "w", "layer.1": "n"}
    assert r.cost == 125.0 and r.loss == pytest.approx(0.011)


def test_greedy_infeasible_budget_flagged():
    sens, costs = _toy_problem()
    r = greedy_search(sens, costs, budget=10.0)
    assert not r.feasible
    assert r.assignment == {"layer.0": "n", "layer.1": "n"}


def test_greedy_loss_retotaled_for_nonmonotone_sensitivity():
    """Noisy profiles can make a narrower scheme measure *lower* loss;
    the reported total must match the returned assignment exactly."""
    sens = {"l0": {"w": {"kl": 0.2}, "m": {"kl": 0.5}, "n": {"kl": 0.4}}}
    costs = {"l0": {"w": {"bytes": 100.0}, "m": {"bytes": 50.0},
                    "n": {"bytes": 25.0}}}
    r = greedy_search(sens, costs, budget=30.0)
    assert r.assignment == {"l0": "n"}
    assert r.loss == pytest.approx(0.4)        # not the clamped 0.5 path


def test_uniform_and_frontier_helpers():
    sens, costs = _toy_problem()
    u = uniform_result("w", sens, costs)
    assert u.cost == 200.0
    pts = pareto_frontier([(200.0, 0.0011), (125.0, 0.011), (50.0, 1.01),
                           (125.0, 0.5)])
    assert pts == [(50.0, 1.01), (125.0, 0.011), (200.0, 0.0011)]


# ---------------------------------------------------------------------------
# acceptance: searched plan strictly inside the uniform frontier
# ---------------------------------------------------------------------------

def test_searched_plan_strictly_inside_uniform_frontier(params):
    from repro.launch.plan import build_plan, make_calib_stream
    stream = make_calib_stream(TINY, n_batches=2, batch=4, seq_len=16)
    cands = CANDS
    prof = profile_sensitivity(params, TINY, stream, cands)
    costs = {l: {s: c.to_dict() for s, c in row.items()}
             for l, row in candidate_costs(TINY, cands).items()}
    u8 = uniform_result("lq8w", prof.losses, costs)
    u2 = uniform_result("lq2w", prof.losses, costs)
    budget = (u8.cost + u2.cost) / 2
    r = greedy_search(prof.losses, costs, budget=budget)
    assert r.feasible
    assert len(set(r.assignment.values())) > 1          # genuinely mixed
    assert r.cost < u8.cost                             # cheaper than 8-bit
    assert r.loss < u2.loss                             # better than 2-bit
    # and the CLI-level wrapper agrees end to end
    plan, result, _ = build_plan(TINY, params, list(cands),
                                 budget_mb=budget / 2**20, batches=stream,
                                 verbose=False)
    assert result.feasible and not plan.is_uniform


def test_plan_pareto_bench_smoke():
    from benchmarks import plan_pareto
    out = plan_pareto.run(verbose=False)
    assert out["mixed_plan_inside_uniform_frontier"]
    assert len(out["frontier"]) >= 3
    json.dumps(out)                                     # JSON-serializable


def test_kv_pareto_bench_mixed_inside_uniform_frontier():
    """The kv acceptance bar: some genuinely mixed per-layer kv map lands
    strictly inside the uniform-kv bytes/token-vs-loss frontier."""
    from benchmarks import plan_pareto
    out = plan_pareto.run_kv(verbose=False)
    assert out["mixed_kv_inside_uniform_frontier"]
    assert any(r["mixed"] for r in out["planned"])
    for r in out["planned"]:                   # cost model exact per plan
        assert set(r["kv_bits"]) == {f"layer.{i}" for i in range(4)}
    json.dumps(out)


def test_joint_build_plan_emits_kv_map(params):
    """CLI-level wrapper: joint profile -> search -> plan with kv_bits."""
    from repro.launch.plan import build_plan, make_calib_stream
    stream = make_calib_stream(TINY, n_batches=1, batch=2, seq_len=16)
    u8 = plan_cost(TINY, (CANDS["lq8w"],) * 4)["bytes"]
    plan, result, _ = build_plan(
        TINY, params, list(CANDS), budget_mb=0.6 * u8 / 2**20,
        batches=stream, verbose=False,
        kv_bits=[8, 4, 2], kv_group=64, kv_tokens=64)
    assert result.feasible and plan.has_kv
    assert plan.kv_group == 16                 # fitted to head_dim
    kv = plan.resolve_kv(TINY)
    assert len(kv) == 4 and all(b in (8, 4, 2) for b in kv)
    with pytest.raises(ValueError, match="budget_mb"):
        build_plan(TINY, params, list(CANDS), budget_ms=1.0,
                   batches=stream, verbose=False, kv_bits=[8, 2])


# ---------------------------------------------------------------------------
# acceptance: planned model serves token-for-token through the paged path
# ---------------------------------------------------------------------------

def test_planned_serve_matches_solo_greedy(params):
    plan = _mixed_plan()
    prompts = [[7, 3, 200, 41, 9], [100, 2, 2, 55, 13, 77, 8]]
    max_new = [9, 7]
    solo = []
    for p, n in zip(prompts, max_new):
        eng = Engine(TINY, params, EngineConfig(max_len=32, plan=plan,
                                                backend="ref"))
        out, _ = eng.generate({"tokens": jnp.asarray([p], jnp.int32)},
                              steps=n - 1)
        solo.append(np.asarray(out)[0].tolist())

    srv = Server(TINY, params,
                 EngineConfig(max_len=32, plan=plan, backend="ref"),
                 PagedConfig(max_slots=2, page_size=4, n_pages=40,
                             max_context=32))
    r0 = srv.submit(prompts[0], RequestParams(max_new_tokens=max_new[0]))
    srv.step()
    r1 = srv.submit(prompts[1], RequestParams(max_new_tokens=max_new[1]))
    outs = srv.drain(max_steps=200)
    assert outs[r0] == solo[0]
    assert outs[r1] == solo[1]
    assert srv.engine.decode_compilations == 1          # one compiled step


def test_engine_rejects_scheme_and_plan(params):
    with pytest.raises(ValueError, match="not both"):
        Engine(TINY, params, EngineConfig(weight_scheme="lq4w",
                                          plan=_mixed_plan()))
    with pytest.raises(ValueError, match="per-layer under a plan"):
        Engine(TINY, params, EngineConfig(a_bits=8, plan=_mixed_plan()))
    with pytest.raises(ValueError, match="per-layer under a plan"):
        Engine(TINY, params, EngineConfig(kv_bits=8, plan=_kv_plan()))


def test_convnet_quantize_rejects_misaligned_region():
    cfg = convnet.MINI_CNN
    params = convnet.init_params(cfg, jax.random.key(0))
    n = convnet.n_quant_layers(cfg)
    bad = (schemes.QuantConfig(w_bits=4, group_size=128),) * n  # fan-in 27
    with pytest.raises(ValueError, match="does not divide fan-in"):
        convnet.quantize_params(params, cfg, bad)
